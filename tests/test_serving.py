"""Serving-plane suite (DESIGN.md §17): wire frames, freshness tiers,
the ModelSlot swap protocol, the padded-batch bitwise pin, the socket
service end to end, and the version contracts against the async engine:

  - INFER/RESULT/STATUS frame round-trips survive adversarial chunking;
    corruption is withheld by the CRC firewall, never parsed.
  - freshness boundaries: exactly-at-threshold is the lower tier; the
    fresh -> soft_stale -> hard_stale transitions run on a controlled
    SimClock along BOTH axes (rounds-behind and seconds-behind).
  - ModelSlot publish is atomic and version-monotonic under concurrent
    publishers; an out-of-order (older) publish is refused.
  - THE padding pin: a request's detections are bit-identical whether it
    shares the fixed-slot batch with 7 other images or rides alone with 7
    zero-padded slots — per-slot decode is a function of that slot alone,
    and the socket path returns exactly the direct program's bits.
  - hot swap under load drops zero requests and post-swap responses carry
    the new round version.
  - the served version ALWAYS equals the engine's landed round version:
    `publish_from_engine` reads the engine's own global snapshot, never a
    buffer row that mid-window holds a client's next in-flight update —
    and the COS restore round-trip (train -> checkpoint -> serve) is
    bit-identical to that same landed global.
"""
import json
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import monitor, serving
from repro.core import rounds as R
from repro.core.simclock import SimClock
from repro.core.transport import harness, replay, wire
from repro.data import synthetic
from repro.models import params as P
from repro.models import yolov3

IMG = 32


def tiny_cfg():
    return get_arch("fedyolov3").reduced()


def tiny_fed(**kw):
    return R.FedConfig(n_clients=2, serve_batch=kw.pop("serve_batch", 4), **kw)


def tiny_params(cfg, seed=0):
    return P.init_params(yolov3.template(cfg), jax.random.key(seed), jnp.float32)


def scenes(n, seed=0, size=IMG, n_classes=3):
    rng = np.random.default_rng(seed)
    imgs, _ = synthetic.scene_images(rng, n, size, n_classes)
    return imgs


# --------------------------- wire frames -------------------------------------

def test_infer_frame_roundtrip_chunked():
    img = np.random.default_rng(0).normal(size=(7, 5, 3)).astype(np.float32)
    frame = wire.pack_infer(42, img)
    parser = wire.FrameParser()
    frames = []
    for i in range(0, len(frame), 3):  # adversarial chunking: 3-byte feeds
        frames.extend(parser.feed(frame[i : i + 3]))
    assert len(frames) == 1 and frames[0][0] == wire.INFER
    rid, out = wire.parse_infer(frames[0][1])
    assert rid == 42
    assert out.dtype == np.float32 and out.shape == (7, 5, 3)
    np.testing.assert_array_equal(out, img)


def test_infer_frame_rejects_bad_shapes():
    with pytest.raises(ValueError, match=r"\(H, W, 3\)"):
        wire.pack_infer(0, np.zeros((4, 4), np.float32))
    rid_hw = wire._INFER.pack(1, 4, 4)
    with pytest.raises(ValueError, match="INFER body"):
        wire.parse_infer(rid_hw + b"\0" * 7)  # truncated image bytes


def test_result_frame_roundtrip():
    dets = [
        (2, np.float32(0.75), (np.float32(0.1), np.float32(0.2),
                               np.float32(0.3), np.float32(0.4))),
        (-1, np.float32(0.5), (np.float32(1.5),) * 4),
    ]
    frame = wire.pack_result(7, 12345, serving.TIER_CODES[serving.SOFT_STALE], dets)
    parser = wire.FrameParser()
    (ftype, payload), = parser.feed(frame)
    assert ftype == wire.RESULT
    rid, version, tier, out = wire.parse_result(payload)
    assert (rid, version, tier) == (7, 12345, 1)
    assert out == [(l, float(s), tuple(float(v) for v in b)) for l, s, b in dets]


def test_status_frame_roundtrip():
    (ftype, payload), = wire.FrameParser().feed(wire.pack_status_request())
    assert ftype == wire.STATUS and wire.parse_status(payload) is None
    status = {"version": 3, "tier": "fresh", "rounds_behind": 0}
    (_, payload), = wire.FrameParser().feed(wire.pack_status(status))
    assert wire.parse_status(payload) == status


def test_corrupted_serving_frame_is_withheld():
    frame = bytearray(wire.pack_infer(1, np.ones((2, 2, 3), np.float32)))
    frame[wire.HEADER_BYTES + 10] ^= 0xFF  # flip one body byte
    parser = wire.FrameParser()
    assert parser.feed(bytes(frame)) == []
    assert parser.crc_errors == 1  # detected, counted, never delivered


# --------------------------- freshness tiers ---------------------------------

def test_freshness_boundaries_rounds_axis():
    fed = tiny_fed()  # soft at >2 rounds, hard at >8
    assert serving.freshness_tier(0, 0.0, fed) == serving.FRESH
    assert serving.freshness_tier(fed.serve_soft_stale_rounds, 0.0, fed) == serving.FRESH
    assert serving.freshness_tier(fed.serve_soft_stale_rounds + 1, 0.0, fed) == serving.SOFT_STALE
    assert serving.freshness_tier(fed.serve_hard_stale_rounds, 0.0, fed) == serving.SOFT_STALE
    assert serving.freshness_tier(fed.serve_hard_stale_rounds + 1, 0.0, fed) == serving.HARD_STALE


def test_freshness_boundaries_seconds_axis():
    fed = tiny_fed()
    assert serving.freshness_tier(0, fed.serve_soft_stale_s, fed) == serving.FRESH
    assert serving.freshness_tier(0, fed.serve_soft_stale_s + 1e-3, fed) == serving.SOFT_STALE
    assert serving.freshness_tier(0, fed.serve_hard_stale_s, fed) == serving.SOFT_STALE
    assert serving.freshness_tier(0, fed.serve_hard_stale_s + 1e-3, fed) == serving.HARD_STALE


def test_freshness_transitions_on_simclock():
    """fresh -> soft -> hard driven by a controlled clock, then by landed
    rounds — the two staleness axes degrade independently."""
    fed = tiny_fed()
    clock = SimClock()
    slot = serving.ModelSlot(clock=clock)
    slot.publish(5, {"w": np.zeros(1)})
    latest = 5

    def tier():
        return serving.model_status(slot, latest, clock.now(), fed)["tier"]

    assert tier() == serving.FRESH
    clock.advance(fed.serve_soft_stale_s + 1.0)
    assert tier() == serving.SOFT_STALE
    clock.advance(fed.serve_hard_stale_s - fed.serve_soft_stale_s)
    assert tier() == serving.HARD_STALE
    # a fresh publish resets the wall axis...
    slot.publish(5, {"w": np.zeros(1)})
    assert tier() == serving.FRESH
    # ...and the rounds axis degrades on its own, clock untouched
    latest = 5 + fed.serve_soft_stale_rounds + 1
    assert tier() == serving.SOFT_STALE
    latest = 5 + fed.serve_hard_stale_rounds + 1
    status = serving.model_status(slot, latest, clock.now(), fed)
    assert status["tier"] == serving.HARD_STALE and status["degraded"]
    assert status["rounds_behind"] == fed.serve_hard_stale_rounds + 1


def test_tier_codes_are_a_bijection():
    assert sorted(serving.TIER_CODES.values()) == [0, 1, 2]
    for name, code in serving.TIER_CODES.items():
        assert serving.TIER_NAMES[code] == name


# --------------------------- ModelSlot ---------------------------------------

def test_modelslot_refuses_version_regression():
    slot = serving.ModelSlot()
    assert slot.publish(3, "v3")
    assert not slot.publish(2, "v2-late")  # an out-of-order publisher
    assert slot.snapshot().version == 3 and slot.snapshot().params == "v3"
    assert slot.stale_publishes == 1 and slot.swaps == 1
    assert slot.publish(3, "v3-again")  # same-version republish is allowed


def test_modelslot_empty_raises_and_service_refuses_start():
    slot = serving.ModelSlot()
    with pytest.raises(RuntimeError, match="empty"):
        slot.snapshot()
    svc = serving.InferenceService(tiny_cfg(), tiny_fed(), slot, img_size=IMG)
    with pytest.raises(RuntimeError, match="publish"):
        svc.start()
    svc.stop()


def test_modelslot_concurrent_publishers_end_at_max_version():
    slot = serving.ModelSlot()
    versions = list(range(1, 33))
    rng = np.random.default_rng(0)
    rng.shuffle(versions)

    def pub(v):
        slot.publish(v, f"params-{v}")

    threads = [threading.Thread(target=pub, args=(v,)) for v in versions]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    final = slot.snapshot()
    assert final.version == 32 and final.params == "params-32"
    assert slot.swaps + slot.stale_publishes == 32


# --------------------------- the padding pin ---------------------------------

def test_padded_batch_is_bit_identical_to_lone_request():
    """THE acceptance pin: slot i's detections depend on slot i alone.

    The same image rides (a) alone with 7 zero-padded slots and (b) in a
    full batch of 8 distinct scenes, through the SAME fixed-slot program —
    every output array for its slot must match bit for bit."""
    cfg, fed = tiny_cfg(), tiny_fed(serve_batch=8)
    params = tiny_params(cfg)
    prog = serving.detection_program(cfg, fed.serve_max_detections)
    imgs = scenes(8, seed=3)
    lone = np.zeros_like(imgs)
    lone[0] = imgs[0]
    full = jax.tree.map(np.asarray, prog(params, jnp.asarray(imgs)))
    alone = jax.tree.map(np.asarray, prog(params, jnp.asarray(lone)))
    for key in ("boxes", "scores", "cls", "valid"):
        np.testing.assert_array_equal(full[key][0], alone[key][0], err_msg=key)
    # and the decoded RESULT payload (the wire's view) agrees too
    assert serving.decode_result(full, 0) == serving.decode_result(alone, 0)
    assert sum(len(serving.decode_result(full, i)) for i in range(8)) > 0


def test_detection_program_is_cached():
    cfg = tiny_cfg()
    assert serving.detection_program(cfg, 16) is serving.detection_program(cfg, 16)
    assert serving.detection_program(cfg, 16) is not serving.detection_program(cfg, 8)


# --------------------------- socket service ----------------------------------

def serve_ctx(fed=None, *, seed=0, version=1, slot=None):
    cfg = tiny_cfg()
    fed = fed or tiny_fed()
    if slot is None:
        slot = serving.ModelSlot()
        slot.publish(version, tiny_params(cfg, seed))
    svc = serving.InferenceService(cfg, fed, slot, img_size=IMG).start()
    return cfg, fed, slot, svc


def test_served_request_matches_direct_program_bitwise():
    cfg, fed, slot, svc = serve_ctx()
    try:
        img = scenes(1, seed=5)[0]
        with serving.InferenceClient(svc.host, svc.port) as client:
            res = client.infer(img)
        pad = np.zeros((fed.serve_batch, IMG, IMG, 3), np.float32)
        pad[0] = img
        prog = serving.detection_program(cfg, fed.serve_max_detections)
        pred = jax.tree.map(np.asarray, prog(slot.snapshot().params, jnp.asarray(pad)))
        assert res.detections == serving.decode_result(pred, 0)
        assert res.version == 1 and res.tier == serving.FRESH
    finally:
        svc.stop()


def test_concurrent_requests_batch_into_shared_launches():
    cfg, fed, slot, svc = serve_ctx(tiny_fed(serve_batch=4))
    try:
        imgs = scenes(8, seed=6)
        with serving.InferenceClient(svc.host, svc.port) as warm:
            warm.infer(imgs[0])  # compile outside the batching window
        with serving.InferenceClient(svc.host, svc.port) as client:
            rids = [client.send_infer(imgs[i]) for i in range(8)]
            results = {client.recv_result().request_id for _ in rids}
        assert results == set(rids)  # every request answered exactly once
        assert svc.stats.in_flight == 0
        # 8 pipelined requests through 4 slots must have shared launches
        assert svc.stats.batches < 1 + 8
        assert svc.stats.avg_occupancy > 1.0
    finally:
        svc.stop()


def test_status_frame_equals_host_evaluator():
    """One evaluator, two callers: the STATUS frame a consumer reads is the
    same `model_status` dict the host/monitor sees (SimClock pins the
    seconds axis so the two calls can be compared exactly)."""
    clock = SimClock()
    slot = serving.ModelSlot(clock=clock)
    cfg, fed = tiny_cfg(), tiny_fed()
    slot.publish(4, tiny_params(cfg))
    svc = serving.InferenceService(cfg, fed, slot, img_size=IMG,
                                   latest_version=lambda: 7).start()
    try:
        with serving.InferenceClient(svc.host, svc.port) as client:
            over_wire = client.status()
        host = svc.status()
        host["status_requests"] = over_wire["status_requests"]  # the frame itself counted
        assert over_wire == host
        assert over_wire["version"] == 4 and over_wire["latest_version"] == 7
        assert over_wire["rounds_behind"] == 3
        assert over_wire["tier"] == serving.SOFT_STALE
    finally:
        svc.stop()


def test_wrong_size_image_is_a_protocol_error():
    _, _, _, svc = serve_ctx()
    try:
        client = serving.InferenceClient(svc.host, svc.port)
        client.send_infer(np.zeros((IMG + 1, IMG + 1, 3), np.float32))
        with pytest.raises(ConnectionError):
            client.recv_result()  # the service dropped the connection
        client.close()
        for _ in range(200):  # reader thread counts it asynchronously
            if svc.stats.protocol_errors:
                break
            time.sleep(0.005)
        assert svc.stats.protocol_errors == 1
        assert svc.stats.requests == 0  # never reached the batcher
    finally:
        svc.stop()


def test_hot_swap_under_load_drops_nothing():
    cfg, fed, slot, svc = serve_ctx()
    try:
        imgs = scenes(4, seed=8)
        with serving.InferenceClient(svc.host, svc.port) as warm:
            warm.infer(imgs[0])
        versions = []
        with serving.InferenceClient(svc.host, svc.port) as client:
            for i in range(6):
                if i == 3:  # swap with requests still streaming
                    assert slot.publish(2, tiny_params(cfg, seed=9))
                versions.append(client.infer(imgs[i % 4]).version)
        assert svc.stats.in_flight == 0  # every INFER answered
        assert versions[0] == 1 and versions[-1] == 2  # post-swap = new round
        assert sorted(set(versions)) == [1, 2]
        assert slot.swaps == 2
    finally:
        svc.stop()


# --------------------- version contract vs the engine ------------------------

def engine_with_landed_round():
    """An arrival engine driven one flush in, plus one MID-WINDOW landing:
    the buffer row indexed by `global_row` now holds client 0's next
    trained update, while the landed global lives only in the engine's own
    snapshot — the exact hazard the serving plane must never serve."""
    meta = harness.make_meta(overrides=dict(harness.TINY_OVERRIDES),
                             n_clients=2, buffer_size=2)
    eng = replay.make_engine(meta)
    rng = np.random.default_rng(0)
    n = eng.state["params"].shape[1]
    for c in (0, 1):  # one full window -> flush -> version 1
        eng.land(c, eng.dispatch_row(c) + rng.normal(size=n).astype(np.float32) * 1e-3)
    assert eng.version == 1
    eng.dispatch(0)
    eng.land(0, eng.dispatch_row(0) + rng.normal(size=n).astype(np.float32) * 1e-3)
    assert eng.staged() == (0,) and eng.global_row == 0  # the hazard is live
    return meta, eng


def test_publish_from_engine_serves_the_landed_global_not_inflight():
    meta, eng = engine_with_landed_round()
    cfg = replay.build_cfg(meta)
    hazard_row = np.asarray(eng.state["params"][eng.global_row])
    landed = np.asarray(eng.global_packed_row())
    assert not np.array_equal(hazard_row, landed)  # mid-window rows differ
    slot = serving.ModelSlot()
    assert serving.publish_from_engine(slot, eng, cfg)
    pub = slot.snapshot()
    assert pub.version == eng.version == 1
    want = serving.unpack_global(cfg, eng.fed, landed)
    got_flat = np.concatenate([np.ravel(x) for x in jax.tree.leaves(pub.params)])
    want_flat = np.concatenate([np.ravel(x) for x in jax.tree.leaves(want)])
    np.testing.assert_array_equal(got_flat, want_flat)
    hazard = serving.unpack_global(cfg, eng.fed, hazard_row)
    hz_flat = np.concatenate([np.ravel(x) for x in jax.tree.leaves(hazard)])
    assert not np.array_equal(got_flat, hz_flat)


def test_restore_roundtrip_is_bit_identical_to_landed_global(tmp_path):
    """train -> COS checkpoint -> serve-side restore: the restored params
    repack to EXACTLY the engine's landed global row, not the stale
    in-flight buffer row (satellite acceptance)."""
    from repro.checkpoint import ObjectStore
    from repro.core import packing

    meta, eng = engine_with_landed_round()
    cfg = replay.build_cfg(meta)
    landed_tree = serving.unpack_global(cfg, eng.fed, eng.global_packed_row())
    store = ObjectStore(tmp_path)
    store.put_model("served", eng.version, landed_tree)
    # the serve side rebuilds the template from cfg alone, then restores
    from repro.models import transformer as T

    template = P.init_params(T.template(cfg), jax.random.key(99), jnp.float32)
    restored = store.restore_into("served", template, round_idx=eng.version)
    spec = packing.build_pack_spec(cfg, T.template(cfg))
    repacked = packing.pack(spec, jax.tree.map(lambda x: x[None], restored), jnp.float32)[0]
    np.testing.assert_array_equal(
        np.asarray(repacked), np.asarray(eng.global_packed_row())
    )
    assert not np.array_equal(
        np.asarray(repacked), np.asarray(eng.state["params"][eng.global_row])
    )
    assert max(store.rounds("served")) == eng.version  # the served version


# --------------------------- monitor -----------------------------------------

def test_render_serving_reports_tier_and_traffic():
    clock = SimClock()
    slot = serving.ModelSlot(clock=clock)
    fed = tiny_fed()
    slot.publish(6, "params")
    stats = serving.ServeStats(requests=10, results=10, batches=3, occupancy_sum=10)
    out = monitor.render_serving(
        "fedyolo", serving.model_status(slot, 6, clock.now(), fed, stats)
    )
    assert "serving round v6" in out and "fresh" in out
    assert "occupancy 3.33" in out and "in flight 0" in out
    clock.advance(fed.serve_hard_stale_s + 1)
    out = monitor.render_serving(
        "fedyolo", serving.model_status(slot, 6, clock.now(), fed)
    )
    assert "hard_stale" in out and "DEGRADED" in out
    assert "traffic" not in out  # no stats given -> no traffic line


def test_render_serving_json_roundtrip_of_status():
    # the STATUS payload is JSON all the way: what the wire carries renders
    clock = SimClock()
    slot = serving.ModelSlot(clock=clock)
    slot.publish(2, None)
    status = serving.model_status(slot, 3, clock.now(), tiny_fed())
    assert monitor.render_serving("t", json.loads(json.dumps(status))).startswith("[t]")


# --------------------------- launcher ----------------------------------------

def test_decode_programs_cache_hits_across_generate_calls():
    from repro.launch import serve as serve_mod
    from repro.models import transformer as T

    cfg = get_arch("qwen3-1.7b").reduced()
    serve_mod.decode_programs.cache_clear()
    a = serve_mod.decode_programs(cfg, 24)
    assert serve_mod.decode_programs(cfg, 24) is a  # no per-call re-jit
    params = P.init_params(T.template(cfg), jax.random.key(0), jnp.float32)
    prompts = jnp.zeros((1, 8), jnp.int32)
    t1 = serve_mod.generate(cfg, params, prompts, 4)
    hits_before = serve_mod.decode_programs.cache_info().hits
    t2 = serve_mod.generate(cfg, params, prompts, 4)
    assert serve_mod.decode_programs.cache_info().hits > hits_before
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


ROOT = Path(__file__).resolve().parents[1]
CLI_ENV = {**os.environ, "PYTHONPATH": str(ROOT / "src")}


def _run_cli(args, timeout=420):
    return subprocess.run(
        [sys.executable, "-m", *args], env=CLI_ENV, cwd=ROOT,
        capture_output=True, text=True, timeout=timeout,
    )


def test_serve_cli_runs_the_service():
    r = _run_cli(["repro.launch.serve", "--arch", "fedyolov3", "--img-size", "32",
                  "--requests", "4", "--serve-batch", "2"])
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["requests"] == 4 and out["dropped"] == 0
    assert out["tier"] == "fresh" and out["qps"] > 0
    assert out["version"] == 0  # no --store: an un-trained v0 model


def test_serve_cli_one_shot_still_decodes():
    r = _run_cli(["repro.launch.serve", "--arch", "fedyolov3", "--img-size", "32",
                  "--batch", "2", "--one-shot"])
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert len(out["detections"]) == 2 and out["images_per_s"] > 0
