"""Attention path equivalences + causality property tests."""
import dataclasses

import numpy as np
from _hyp import given, settings, st

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import attention as A
from repro.models import params as P

CFG = dataclasses.replace(get_arch("gemma3-27b").reduced(), window=8, qk_norm=False)


def _qkv(B=2, S=32, H=4, Hkv=2, hd=16, seed=0):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, S, Hkv, hd)), jnp.float32)
    return q, k, v


def test_windowed_equals_masked_full():
    q, k, v = _qkv(S=32)
    W = 8
    got = A.windowed_attention(q, k, v, window=W)
    qp = jnp.arange(32)[:, None]
    kp = jnp.arange(32)[None, :]
    mask = ((qp >= kp) & (qp - kp < W))[None, None, None]
    want = A._sdpa(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_q_chunked_equals_full():
    q, k, v = _qkv(S=64)
    got = A._q_chunked_attention(q, k, v, causal=True, q_chunk=16)
    want = A._sdpa(q, k, v, (jnp.arange(64)[:, None] >= jnp.arange(64)[None, :])[None, None, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@given(st.integers(0, 30))
@settings(max_examples=8, deadline=None)
def test_causality_future_tokens_do_not_matter(t):
    """Output at position t is unchanged by any perturbation of tokens > t."""
    cfg = get_arch("qwen3-1.7b").reduced()
    from repro.models import transformer as T

    tpl = T.template(cfg)
    params = P.init_params(tpl, jax.random.key(0), jnp.float32)
    toks = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab_size, (1, 32)), jnp.int32)
    toks2 = toks.at[0, t + 1 :].set((toks[0, t + 1 :] + 7) % cfg.vocab_size)
    h1, _ = T.trunk(cfg, params, T.embed_inputs(cfg, params, {"tokens": toks}))
    h2, _ = T.trunk(cfg, params, T.embed_inputs(cfg, params, {"tokens": toks2}))
    np.testing.assert_allclose(
        np.asarray(h1[0, : t + 1]), np.asarray(h2[0, : t + 1]), rtol=1e-4, atol=1e-4
    )


def test_ring_buffer_decode_matches_masked_full():
    """Windowed ring-buffer decode == full attention with window mask."""
    cfg = CFG
    tpl = A.attention_template(cfg, (), ())
    params = P.init_params(tpl, jax.random.key(3), jnp.float32)
    B, S, W = 1, 24, cfg.window
    r = np.random.default_rng(5)
    xs = jnp.asarray(r.normal(size=(B, S, cfg.d_model)) * 0.3, jnp.float32)
    # full path with window masking
    want = A.attention_block(params, xs, cfg, window=W)
    # decode path, token by token with a ring cache of size W
    cache = {
        "k": jnp.zeros((B, W, cfg.n_kv_heads, cfg.resolved_head_dim)),
        "v": jnp.zeros((B, W, cfg.n_kv_heads, cfg.resolved_head_dim)),
    }
    outs = []
    for t in range(S):
        o, cache = A.decode_attention(params, xs[:, t : t + 1], cache, cfg, jnp.int32(t), window=W)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_gqa_grouping_matches_repeated_kv():
    """GQA == MHA with kv heads repeated G times."""
    q, k, v = _qkv(H=4, Hkv=2)
    out_gqa = A.full_attention(q, k, v, causal=True)
    k_rep = jnp.repeat(k, 2, axis=2)
    v_rep = jnp.repeat(v, 2, axis=2)
    out_mha = A.full_attention(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha), rtol=2e-5, atol=2e-5)


@given(st.integers(2, 64))
@settings(max_examples=10, deadline=None)
def test_rope_preserves_norm(S):
    """RoPE is a rotation: per-position vector norms are unchanged."""
    from repro.models.layers import apply_rope, rope_freqs

    r = np.random.default_rng(S)
    x = jnp.asarray(r.normal(size=(1, S, 2, 32)), jnp.float32)
    cos, sin = rope_freqs(jnp.arange(S), 32, 1e4)
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_position_property():
    """q_i . k_j after RoPE depends only on (i - j): shifting both positions
    by a constant leaves the attention score unchanged."""
    from repro.models.layers import apply_rope, rope_freqs

    r = np.random.default_rng(0)
    hd = 32
    q = jnp.asarray(r.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(1, 1, 1, hd)), jnp.float32)

    def score(i, j):
        cq = rope_freqs(jnp.asarray([i]), hd, 1e4)
        ck = rope_freqs(jnp.asarray([j]), hd, 1e4)
        return float(jnp.sum(apply_rope(q, *cq) * apply_rope(k, *ck)))

    np.testing.assert_allclose(score(5, 3), score(105, 103), rtol=1e-4)
    assert abs(score(5, 3) - score(5, 4)) > 1e-6  # but it does depend on i-j


@given(st.floats(0.5, 4.0))
@settings(max_examples=10, deadline=None)
def test_rms_norm_scale_invariance(c):
    """rms_norm(c*x) == rms_norm(x) for any positive scalar c."""
    from repro.models.layers import rms_norm

    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(2, 8, 16)), jnp.float32)
    w = jnp.asarray(r.normal(size=(16,)) * 0.1, jnp.float32)
    a = rms_norm(x, w)
    b = rms_norm(c * x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3, atol=3e-4)
