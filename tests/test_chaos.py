"""Crash-tolerance suite (DESIGN.md §16): durable round state, server
recovery, worker retry/backoff, and the deterministic fault layer.

Three tiers, cheapest first:

  - pure-unit: the `retry.Backoff` schedule (deterministic, seeded,
    bounded), the `faults.FaultPlan` grammar and its per-op persistent
    counters, the snapshot file format and WAL line discipline
    (`checkpoint.durable`) — no engine, no sockets beyond socketpairs;
  - in-process engine: `export_state`/`import_state` round-trips MID
    aggregation window with ``topk_ef`` (error-feedback residuals and
    fmix32 round counters are aggregator-private leaves — exactly the
    state a naive params-only checkpoint would lose), and
    `DurableRun.recover_engine` pinned bitwise against an uninterrupted
    engine driven over the same events;
  - real wire: kill the server mid-round with ``kill@M``, restore from
    snapshot+WAL on the SAME port while worker processes ride their
    backoff loops, and pin the recovered run's final global against a
    SimClock replay of the COMBINED (WAL) schedule — bit-for-bit dense,
    1e-5 under quant8. Plus the storm scenarios: corrupted frames are
    counted and survived (CRC firewall + reconnect), dropped dispatches
    are covered by the worker's dispatch timeout, duplicated updates die
    at the version-echo gate, severed connections reconnect, and every
    injected fault shows up in the counters.
"""
import json
import os
import socket
import threading
import time
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import durable as dr
from repro.checkpoint.store import ObjectStore
from repro.core import async_engine as ae
from repro.core.simclock import SimClock, WallClock
from repro.core.transport import codec, harness, wire
from repro.core.transport import replay as rp
from repro.core.transport.faults import FaultPlan, ServerKilled
from repro.core.transport.retry import Backoff, RetriesExhausted, connect_with_retry

TINY = harness.TINY_OVERRIDES


def _meta(**kw):
    base = dict(overrides=TINY, n_clients=3, buffer_size=2, max_staleness=1,
                seq=8, batch=2)
    base.update(kw)
    return harness.make_meta(**base)


# ---------------------------------------------------------------------------
# retry.Backoff — the deterministic reconnect schedule
# ---------------------------------------------------------------------------

def test_backoff_schedule_is_deterministic():
    a = Backoff(base=0.05, cap=2.0, attempts=8, seed=3)
    b = Backoff(base=0.05, cap=2.0, attempts=8, seed=3)
    assert a.delays() == b.delays()
    assert len(a.delays()) == 7  # no sleep after the final attempt


def test_backoff_seeds_desynchronize_the_stampede():
    # C workers restarted together must NOT sleep identical schedules
    schedules = [tuple(Backoff(seed=c).delays()) for c in range(8)]
    assert len(set(schedules)) == len(schedules)


def test_backoff_delays_grow_and_cap():
    bo = Backoff(base=0.1, cap=0.8, attempts=10, jitter=0.0)
    d = bo.delays()
    assert d[:4] == [0.1, 0.2, 0.4, 0.8]
    assert all(x == 0.8 for x in d[4:])  # capped, never unbounded
    # jitter only ever shortens a delay (never pushes past the cap)
    jit = Backoff(base=0.1, cap=0.8, attempts=10, jitter=0.5, seed=1).delays()
    assert all(0 < j <= x for j, x in zip(jit, d))


def test_backoff_validates_arguments():
    with pytest.raises(ValueError):
        Backoff(base=0.0)
    with pytest.raises(ValueError):
        Backoff(base=1.0, cap=0.5)
    with pytest.raises(ValueError):
        Backoff(jitter=1.0)
    with pytest.raises(ValueError):
        Backoff(attempts=0)


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def test_connect_retry_exhausts_with_the_exact_schedule():
    bo = Backoff(base=0.01, cap=0.02, attempts=4, seed=5)
    slept = []
    with pytest.raises(RetriesExhausted) as ei:
        connect_with_retry("127.0.0.1", _free_port(), bo,
                           timeout=0.2, sleep=slept.append)
    assert slept == bo.delays()  # the sleeps ARE the deterministic schedule
    assert isinstance(ei.value.__cause__, OSError)  # last failure chained


def test_connect_retry_succeeds_once_the_server_binds():
    # the listener appears only after the first refusal — the race the
    # single create_connection call used to lose
    port = _free_port()
    listener = socket.socket()
    attempts = []

    def sleep(_):
        attempts.append(1)
        if len(attempts) == 2:
            listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            listener.bind(("127.0.0.1", port))
            listener.listen(1)

    try:
        sock = connect_with_retry("127.0.0.1", port,
                                  Backoff(base=0.001, attempts=8), sleep=sleep)
        sock.close()
    finally:
        listener.close()
    assert len(attempts) == 2  # refused twice, connected on the third


# ---------------------------------------------------------------------------
# faults.FaultPlan — grammar, counters, socket wrapping
# ---------------------------------------------------------------------------

def test_fault_plan_parses_the_grammar():
    plan = FaultPlan.parse(
        "corrupt@2:update, server.drop@1:dispatch; delay@3:heartbeat:0.5;"
        "sever@4096; kill@7"
    )
    kinds = [(op.side, op.kind, op.arg, op.ftype) for op in plan.ops]
    assert kinds == [
        ("client", "corrupt", 2, wire.UPDATE),
        ("server", "drop", 1, wire.DISPATCH),
        ("client", "delay", 3, wire.HEARTBEAT),
        ("client", "sever", 4096, None),
        ("server", "kill", 7, None),  # kill is forced server-side
    ]
    assert plan.ops[2].seconds == 0.5
    assert plan.total_fired == 0


@pytest.mark.parametrize("bad", [
    "", "  ;  ", "explode@1", "martian.drop@1", "drop", "drop@0",
    "delay@1:update",  # delay without a :seconds qualifier
])
def test_fault_plan_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def _pair():
    a, b = socket.socketpair()
    return a, b


def _drain(sock, parser, timeout=2.0):
    sock.settimeout(timeout)
    frames = []
    try:
        while True:
            data = sock.recv(1 << 16)
            if not data:
                break
            frames.extend(parser.feed(data))
    except socket.timeout:
        pass
    return frames


def test_corrupt_fault_is_caught_by_the_crc_not_by_desync():
    plan = FaultPlan.parse("corrupt@1:update", seed=9)
    a, b = _pair()
    try:
        fa = plan.wrap(a, side="client")
        fa.sendall(wire.pack_update(0, 0, 1, 0.5, b"\x00" * 64))
        fa.sendall(wire.pack_hello(0))  # the stream must stay framed after
        a.close()
        parser = wire.FrameParser()
        frames = _drain(b, parser)
    finally:
        b.close()
    assert plan.total_fired == 1 and plan.fired == {"corrupt@1:update": 1}
    assert parser.crc_errors == 1  # the damaged update was withheld
    assert [t for t, _ in frames] == [wire.HELLO]  # the next frame parsed fine
    assert parser.pending == 0


def test_drop_and_dup_faults_edit_the_frame_stream():
    plan = FaultPlan.parse("drop@1:heartbeat;dup@1:hello")
    a, b = _pair()
    try:
        fa = plan.wrap(a, side="client")
        fa.sendall(wire.pack_heartbeat(3))  # swallowed
        fa.sendall(wire.pack_hello(3))     # doubled
        fa.sendall(wire.pack_bye())
        a.close()
        frames = _drain(b, wire.FrameParser())
    finally:
        b.close()
    assert [t for t, _ in frames] == [wire.HELLO, wire.HELLO, wire.BYE]
    assert plan.total_fired == 2


def test_delay_fault_sleeps_before_sending():
    plan = FaultPlan.parse("delay@1:bye:0.2")
    a, b = _pair()
    try:
        fa = plan.wrap(a, side="client")
        t0 = time.monotonic()
        fa.sendall(wire.pack_bye())
        took = time.monotonic() - t0
    finally:
        a.close()
        b.close()
    assert took >= 0.2
    assert plan.fired == {"delay@1:bye:0.2": 1}


def test_sever_fault_slams_the_connection_and_counts():
    plan = FaultPlan.parse("sever@10")
    a, b = _pair()
    try:
        fa = plan.wrap(a, side="client")
        with pytest.raises(ConnectionResetError):
            fa.sendall(wire.pack_update(0, 0, 1, 0.0, b"\x00" * 32))
    finally:
        a.close()
        b.close()
    assert plan.total_fired == 1


def test_fault_counters_persist_across_reconnects():
    # drop@1:update must fire ONCE per plan, not once per wrapped socket —
    # otherwise the worker's retrained update would be swallowed forever
    plan = FaultPlan.parse("drop@1:update")
    got = []
    for _ in range(2):  # two sessions, same plan
        a, b = _pair()
        try:
            fa = plan.wrap(a, side="client")
            fa.sendall(wire.pack_update(0, 0, 1, 0.0, b"\x01"))
            a.close()
            got.append(len(_drain(b, wire.FrameParser())))
        finally:
            b.close()
    assert got == [0, 1]  # first swallowed, second delivered
    assert plan.total_fired == 1


def test_type_qualifier_counts_only_matching_frames():
    plan = FaultPlan.parse("drop@2:update")
    a, b = _pair()
    try:
        fa = plan.wrap(a, side="client")
        # heartbeats interleave racily in real runs: they must not advance
        # the update counter or the plan stops being deterministic
        fa.sendall(wire.pack_heartbeat(0))
        fa.sendall(wire.pack_update(0, 0, 1, 0.0, b"\x01"))
        fa.sendall(wire.pack_heartbeat(0))
        fa.sendall(wire.pack_update(0, 1, 1, 0.0, b"\x01"))  # the 2nd update
        fa.sendall(wire.pack_heartbeat(0))
        a.close()
        frames = _drain(b, wire.FrameParser())
    finally:
        b.close()
    types = [t for t, _ in frames]
    assert types.count(wire.UPDATE) == 1
    assert types.count(wire.HEARTBEAT) == 3


def test_kill_trigger_fires_once_at_threshold():
    plan = FaultPlan.parse("kill@3")
    assert plan.kill_after_landings() == 3
    plan.maybe_kill(1)
    plan.maybe_kill(2)
    with pytest.raises(ServerKilled):
        plan.maybe_kill(3)
    plan.maybe_kill(99)  # done ops never re-fire: the restored server lives
    assert plan.kill_after_landings() is None
    assert plan.fired == {"kill@3": 1}


# ---------------------------------------------------------------------------
# checkpoint.durable — snapshot file format + WAL discipline
# ---------------------------------------------------------------------------

def _fake_snap(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "arrays": {
            "params": rng.normal(size=(3, 17)).astype(np.float32),
            "agg_0": rng.normal(size=17).astype(np.float32),
            "counter": np.asarray([5], np.uint32),
        },
        "scalars": {"round": 4, "version": 4, "losses": [0.5, 0.25]},
    }


def test_snapshot_file_roundtrip_is_bitwise(tmp_path):
    snap = _fake_snap()
    n = dr.write_snapshot(tmp_path / "s.ckpt", snap)
    assert n == (tmp_path / "s.ckpt").stat().st_size
    back = dr.read_snapshot(tmp_path / "s.ckpt")
    assert back["scalars"] == snap["scalars"]
    assert set(back["arrays"]) == set(snap["arrays"])
    for k, v in snap["arrays"].items():
        np.testing.assert_array_equal(back["arrays"][k], v)
        assert back["arrays"][k].dtype == v.dtype


def test_snapshot_crc_rejects_every_kind_of_damage(tmp_path):
    p = tmp_path / "s.ckpt"
    dr.write_snapshot(p, _fake_snap())
    blob = p.read_bytes()
    # flipped body byte -> CRC mismatch
    bad = bytearray(blob)
    bad[len(blob) // 2] ^= 0xFF
    p.write_bytes(bytes(bad))
    with pytest.raises(ValueError):
        dr.read_snapshot(p)
    # truncation (the torn-write model atomic rename prevents, belt+braces)
    p.write_bytes(blob[:-7])
    with pytest.raises(ValueError):
        dr.read_snapshot(p)
    # wrong magic
    p.write_bytes(b"NOTASNAP" + blob[8:])
    with pytest.raises(ValueError):
        dr.read_snapshot(p)


def test_atomic_write_leaves_no_tmp_file(tmp_path):
    dr.atomic_write_bytes(tmp_path / "x.bin", b"payload")
    assert (tmp_path / "x.bin").read_bytes() == b"payload"
    assert list(tmp_path.glob("*.tmp")) == []


def _events(n, start=0):
    return [rp.WireEvent("dispatch", float(i), i % 3, i) for i in range(start, n)]


def test_wal_torn_tail_is_discarded_not_fatal(tmp_path):
    run = dr.DurableRun(tmp_path, {"n": 1})
    for ev in _events(5):
        run.append_event(ev)
    run.close()
    wal = next(tmp_path.glob("wal_*.jsonl"))
    text = wal.read_text()
    wal.write_text(text[: len(text) - 9])  # the crash tore the last line
    run2 = dr.DurableRun(tmp_path)
    evs = run2.events()
    assert len(evs) == 4  # everything before the torn line is intact
    assert [e.version for e in evs] == [0, 1, 2, 3]
    # ... and a bit-flipped line mid-file ends its segment at that point
    lines = text.splitlines(keepends=True)
    lines[2] = lines[2].replace(lines[2][0], "f" if lines[2][0] != "f" else "0", 1)
    wal.write_text("".join(lines))
    assert len(dr.DurableRun(tmp_path).events()) == 2


def test_wal_segments_concatenate_across_rotations(tmp_path):
    class _Eng:  # snapshot() only needs export_state()
        def export_state(self):
            return _fake_snap()

    run = dr.DurableRun(tmp_path, {"n": 1})
    evs = _events(7)
    for i, ev in enumerate(evs):
        run.append_event(ev)
        if i in (2, 4):
            run.snapshot(_Eng())  # rotates the WAL segment
    run.close()
    assert len(list(tmp_path.glob("wal_*.jsonl"))) == 3
    assert len(list(tmp_path.glob("snap_*.ckpt"))) == 2
    got = dr.DurableRun(tmp_path).events()
    assert [dataclass_tuple(e) for e in got] == [dataclass_tuple(e) for e in evs]


def dataclass_tuple(ev):
    return (ev.kind, ev.t, ev.client, ev.version, ev.seq, ev.dropped, ev.flush)


def test_wal_gap_is_an_error_not_silent_loss(tmp_path):
    run = dr.DurableRun(tmp_path, {"n": 1})

    class _Eng:
        def export_state(self):
            return _fake_snap()

    for i, ev in enumerate(_events(6)):
        run.append_event(ev)
        if i == 2:
            run.snapshot(_Eng())
    run.close()
    first = sorted(tmp_path.glob("wal_*.jsonl"))[0]
    first.unlink()  # lose the first segment entirely
    with pytest.raises(ValueError, match="WAL gap"):
        dr.DurableRun(tmp_path).events()


def test_durable_reopen_resumes_the_event_counter(tmp_path):
    run = dr.DurableRun(tmp_path, {"n": 1})
    for ev in _events(3):
        run.append_event(ev)
    run.close()
    run2 = dr.DurableRun(tmp_path)  # a restarted server reopens the dir
    assert run2.n_events == 3
    run2.append_event(rp.WireEvent("dispatch", 9.0, 0, 3))
    run2.close()
    assert len(dr.DurableRun(tmp_path).events()) == 4
    assert dr.DurableRun(tmp_path).meta == {"n": 1}


def test_durable_run_requires_meta_on_first_open(tmp_path):
    with pytest.raises(FileNotFoundError):
        dr.DurableRun(tmp_path / "fresh")


# ---------------------------------------------------------------------------
# in-process recovery: export/import + recover_engine == uninterrupted run
# ---------------------------------------------------------------------------

def _drive(meta, n_lands, *, durable=None, snapshot_at=()):
    """The server's landing loop in miniature: round-robin dispatch/land
    over a fresh engine, recording every event (and optionally journaling
    it). Returns (engine, events) — the reference a recovery must match."""
    eng = rp.make_engine(meta, clock=SimClock())
    cfg = rp.build_cfg(meta)
    update = ae.build_row_update(
        cfg, rp.build_fed(meta), rp.build_optimizer(meta),
        spec=eng.agg.ctx.spec, template=eng.agg.ctx.template,
    )
    wc, block = meta["wire_codec"], int(meta["quant_block"])
    C = int(meta["n_clients"])
    events, seqs, staged = [], [0] * C, set()
    t = 0.0

    def record(ev):
        events.append(ev)
        if durable is not None:
            durable.append_event(ev)

    for c in range(C):
        t += 1.0
        eng.clock.advance_to(t)
        record(rp.WireEvent("dispatch", t, c, eng.dispatch(c)))
    lands, ci = 0, 0
    while lands < n_lands:
        c = ci % C
        ci += 1
        if c in staged:
            continue  # a staged row waits for its flush redispatch
        t += 1.0
        ver = int(eng.dispatch_version[c])  # echo BEFORE landing moves it
        base = np.asarray(eng.state["params"][c], np.float32)
        batch = rp.synth_client_batch(cfg, meta, c, seqs[c])
        trained, loss = update(jnp.asarray(base), batch)
        landed = codec.decode_update(
            codec.encode_update(np.asarray(trained, np.float32), base, wc, block),
            base,
        )
        res = eng.land(c, landed, loss=float(loss), t=t)
        record(rp.WireEvent(
            "land", t, c, ver, seq=seqs[c], dropped=res.dropped,
            flush=-1 if res.flush is None else res.flush.round_idx,
        ))
        seqs[c] += 1
        lands += 1
        if res.flush is not None:
            staged.clear()
        elif not res.dropped:
            staged.add(c)
        if durable is not None and lands in snapshot_at:
            durable.snapshot(eng)
    return eng, events


def _assert_engines_identical(a, b):
    """Bitwise equality of EVERYTHING export_state covers: packed params,
    the engine's global copy, dispatch versions, and every aggregator
    leaf (EF residuals, fmix32 counters) plus the host-side scalars."""
    sa, sb = a.export_state(), b.export_state()
    assert set(sa["arrays"]) == set(sb["arrays"])
    for k in sa["arrays"]:
        np.testing.assert_array_equal(sa["arrays"][k], sb["arrays"][k], err_msg=k)
    # n_history is informational: round RECORDS are host-side dataclasses a
    # snapshot can't carry — recovery re-earns them by replaying the WAL
    # suffix (and the harness splices the pre-crash prefix back in)
    drop = {"n_history"}
    assert {k: v for k, v in sa["scalars"].items() if k not in drop} == \
           {k: v for k, v in sb["scalars"].items() if k not in drop}


def test_export_import_roundtrips_mid_window_with_topk_ef():
    # topk_ef carries aggregator-private leaves (error-feedback residual
    # rows + round counters) that params-only checkpointing would lose;
    # 4 landings with buffer_size=2 leaves the window HALF FULL — the
    # hardest point to snapshot
    meta = _meta(aggregation="topk_ef", buffer_size=2)
    eng, _ = _drive(meta, 5)
    fresh = rp.make_engine(meta, clock=SimClock())
    fresh.import_state(eng.export_state())
    _assert_engines_identical(eng, fresh)
    assert fresh.version == eng.version
    assert fresh.dropped_total == eng.dropped_total


def test_recover_engine_equals_uninterrupted_run(tmp_path):
    # the tentpole invariant, in-process: snapshot after 3 landings + WAL
    # suffix replayed == the engine that never crashed, bit for bit —
    # including the EF residuals only export_state knows to save
    meta = _meta(aggregation="topk_ef", buffer_size=2)
    run = dr.DurableRun(tmp_path, meta)
    ref, events = _drive(meta, 6, durable=run, snapshot_at=(3,))
    run.close()
    rec, n_replayed = dr.DurableRun(tmp_path).recover_engine(clock=SimClock())
    assert 0 < n_replayed < len(events)  # the snapshot really cut the replay
    _assert_engines_identical(ref, rec)
    # history re-earned by the suffix replay is a SUFFIX of the reference's
    got = [(r.round_idx, r.loss) for r in rec.history]
    assert got and got == [(r.round_idx, r.loss) for r in ref.history][-len(got):]


def test_recover_engine_without_snapshot_degrades_to_full_replay(tmp_path):
    meta = _meta(buffer_size=2)
    run = dr.DurableRun(tmp_path, meta)
    ref, events = _drive(meta, 4, durable=run)
    run.close()
    rec, n_replayed = dr.DurableRun(tmp_path).recover_engine(clock=SimClock())
    assert n_replayed == len(events)  # no snapshot: the WAL alone suffices
    _assert_engines_identical(ref, rec)


def test_recover_engine_falls_back_past_a_corrupt_snapshot(tmp_path):
    meta = _meta(buffer_size=2)
    run = dr.DurableRun(tmp_path, meta)
    ref, _ = _drive(meta, 6, durable=run, snapshot_at=(2, 4))
    run.close()
    newest = sorted(tmp_path.glob("snap_*.ckpt"))[-1]
    blob = bytearray(newest.read_bytes())
    blob[-3] ^= 0x55  # damage the newest snapshot's body
    newest.write_bytes(bytes(blob))
    run2 = dr.DurableRun(tmp_path)
    at, _snap = run2.latest_snapshot()  # fell back to the older one
    assert f"snap_{at:08d}.ckpt" != newest.name
    rec, _ = run2.recover_engine(clock=SimClock())
    _assert_engines_identical(ref, rec)


def test_wall_clock_start_offset_continues_the_timeline():
    # a recovered server's clock resumes AT the crash point, never rewinds:
    # the combined schedule's stamps must stay monotonic across the splice
    clk = WallClock(start=123.5)
    assert clk.now() == 123.5
    time.sleep(0.01)
    assert clk.sync() > 123.5  # host time accrues ON TOP of the offset
    assert clk.peek() >= clk.now()


# ---------------------------------------------------------------------------
# checkpoint.store satellites — atomic manifest, named KeyErrors
# ---------------------------------------------------------------------------

def test_manifest_write_is_atomic(tmp_path):
    store = ObjectStore(tmp_path / "store")
    store.put_model("taskA", 0, {"w": np.zeros(3, np.float32)})
    assert list((tmp_path / "store").rglob("*.tmp")) == []
    # a stale tmp from a crashed writer must not confuse a reopen
    (tmp_path / "store" / "manifest.json.tmp").write_text("garbage{{{")
    again = ObjectStore(tmp_path / "store")
    assert "taskA" in again.manifest


def test_get_model_keyerror_names_what_exists(tmp_path):
    store = ObjectStore(tmp_path / "store")
    store.put_model("taskA", 0, {"w": np.zeros(3, np.float32)})
    with pytest.raises(KeyError, match="taskA"):
        store.get_model("nope", 0)
    with pytest.raises(KeyError, match=r"round"):
        store.get_model("taskA", 7)


# ---------------------------------------------------------------------------
# real wire: kill + restore, storms, and the counters that prove it
# ---------------------------------------------------------------------------

def _pin_replay(res):
    eng = rp.replay(res.schedule)
    np.testing.assert_array_equal(
        np.asarray(eng.global_packed_row(), np.float32), res.global_row
    )
    return eng


# recovery includes a fresh jit compile; workers must outlast it
_PATIENT = ["--connect-retries", "60", "--backoff-max", "1.0"]


@pytest.mark.parametrize("wire_codec,tol", [("dense", 0.0), ("quant8", 1e-5)])
def test_wire_kill_and_restore_pins_the_combined_replay(tmp_path, wire_codec, tol):
    """THE acceptance pin: kill the server after 5 landings (kill -9
    model: no BYE, sockets slammed, WAL torn wherever it was), restore
    from snapshot+WAL on the same port while the workers ride their
    backoff loops, finish the run — and the COMBINED schedule replays to
    the same global (bit-for-bit dense, 1e-5 quant8, with the replay
    cross-checking every dispatch version / drop / flush on the way)."""
    meta = _meta(n_clients=4, buffer_size=2, max_staleness=2,
                 wire_codec=wire_codec)
    res = harness.wire_run(
        meta, 5,
        worker_groups=[{"client_ids": [0, 1, 2, 3], "extra": _PATIENT}],
        deadline_s=150.0,
        durable_root=tmp_path / "run",
        snapshot_every=2,
        fault_plan="kill@5",
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.recovered and res.stats.crashed
    assert res.stats.flushes == 5
    assert res.stats.recoveries == 1
    assert res.stats.faults_injected == 1  # the kill itself, counted
    assert res.stats.snapshots >= 1 and res.stats.wal_events > 0
    assert res.pre_crash_stats is not None and res.pre_crash_stats.landed == 5
    eng = rp.replay(res.schedule)
    got = np.asarray(eng.global_packed_row(), np.float32)
    if tol == 0.0:
        np.testing.assert_array_equal(got, res.global_row)
    else:
        np.testing.assert_allclose(got, res.global_row, atol=tol)
    # the WAL-derived schedule spans the crash: flush count matches too
    assert res.schedule.n_flushes == 5


def test_wire_corrupt_frame_storm_is_counted_and_survived():
    # two corrupted uploads: the server's CRC firewall withholds each,
    # poisons the connection, and the worker reconnects + retrains —
    # damage is COUNTED (crc_errors) and the run still converges + replays
    meta = _meta(n_clients=2, buffer_size=2, max_staleness=2)
    res = harness.wire_run(
        meta, 3,
        worker_groups=[{"client_ids": [0, 1], "extra": _PATIENT}],
        deadline_s=150.0,
        fault_plan="corrupt@2:update;corrupt@4:update",
        fault_seed=11,
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 3
    assert res.stats.crc_errors == 2
    assert res.stats.reconnects >= 1  # poisoned connections were re-made
    _pin_replay(res)


def test_wire_dropped_dispatch_covered_by_dispatch_timeout():
    # with ONE client there is no flush-boundary redispatch to another
    # client that could paper over the loss: when the post-flush dispatch
    # evaporates, the lone worker MUST hit --dispatch-timeout, reconnect,
    # and get redispatched via the fresh HELLO — the black-hole coverage
    meta = _meta(n_clients=1, buffer_size=1, max_staleness=2)
    res = harness.wire_run(
        meta, 3,
        worker_groups=[{
            "client_ids": [0],
            "extra": _PATIENT + ["--dispatch-timeout", "3.0"],
        }],
        deadline_s=150.0,
        fault_plan="server.drop@2:dispatch",
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 3
    assert res.stats.faults_injected == 1  # the drop fired and was counted
    assert res.stats.reconnects >= 1  # the timeout path re-made the session
    _pin_replay(res)


def test_wire_duplicated_update_dies_at_the_version_echo_gate():
    # dup@1:update sends the first upload twice: the first copy lands and
    # redispatches, so the duplicate echoes a version the engine already
    # moved past — refused as superseded, never landed twice
    meta = _meta(n_clients=2, buffer_size=1, max_staleness=2)
    res = harness.wire_run(
        meta, 3,
        worker_groups=[{"client_ids": [0, 1], "extra": _PATIENT}],
        deadline_s=150.0,
        fault_plan="dup@1:update",
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 3
    assert res.stats.superseded >= 1
    lands = [e for e in res.schedule.events if e.kind == "land"]
    seqs = [(e.client, e.seq) for e in lands]
    assert len(seqs) == len(set(seqs))  # no (client, seq) landed twice
    _pin_replay(res)


def test_wire_severed_connection_reconnects_and_completes():
    meta = _meta(n_clients=2, buffer_size=2, max_staleness=2)
    res = harness.wire_run(
        meta, 3,
        worker_groups=[{"client_ids": [0, 1], "extra": _PATIENT}],
        deadline_s=150.0,
        fault_plan="sever@9000",  # mid-run, after the HELLOs + first bytes
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 3
    assert res.stats.reconnects >= 1
    _pin_replay(res)


def test_wire_kill_without_durable_raises_not_hangs():
    # chaos without durability is an error the harness surfaces, never a
    # silent hang: the workers' bounded backoff drains them afterwards
    meta = _meta(n_clients=2, buffer_size=1)
    with pytest.raises(ServerKilled):
        harness.wire_run(
            meta, 4,
            worker_groups=[{
                "client_ids": [0, 1],
                "extra": ["--connect-retries", "2", "--backoff-base", "0.05"],
            }],
            deadline_s=150.0,
            fault_plan="kill@2",
        )


def test_worker_process_exits_cleanly_when_server_never_binds(tmp_path):
    # the backoff-under-refused-connect satellite: a worker pointed at a
    # dead port retries its bounded schedule and exits 0 — no crash, no hang
    meta = _meta(n_clients=1)
    meta_path = tmp_path / "meta.json"
    meta_path.write_text(json.dumps(meta))
    p = harness.spawn_worker(
        str(meta_path), "127.0.0.1", _free_port(), [0],
        ["--connect-retries", "3", "--backoff-base", "0.01"],
    )
    _, err = p.communicate(timeout=120)
    assert p.returncode == 0, err.decode()
