"""Buffered async round engine on the simulated wall clock (DESIGN.md §12).

Pins the tentpole invariants:
  - sync-equivalence contract: buffer_size == C + zero-variance load model
    + alpha = 0 reproduces the flat sync round BIT-FOR-BIT (params, opt,
    agg state, loss) — the full-buffer flush IS the sync round program;
  - the event queue is deterministic: equal completion times pop in client
    id order (heap tie-break), so replays are exact;
  - max_staleness drops are *counted*, never silently lost (completions ==
    staged + dropped), and the dropped client redispatches from the
    current global;
  - staleness weights fold into the packed reduce's weights operand and
    need not sum to 1 — the reducer normalizes by its own denominator;
  - the time-based Explorer fix: step(dt) advances simulated seconds,
    spike durations outlive step calls, and the legacy one-call-per-round
    cadence reproduces the old process bit-for-bit.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import aggregators, monitor, packing
from repro.core import rounds as R
from repro.core.async_engine import (
    AsyncRoundRecord,
    BufferedAsyncEngine,
    TimingModel,
    client_upload_seconds,
    sync_round_seconds,
)
from repro.core.explorer import ClientLoadModel, LoadModelConfig
from repro.core.rounds import FedConfig
from repro.core.server import FLServer
from repro.core.simclock import SimClock
from repro.core.task_manager import FederatedTask, TaskManager
from repro.optim import sgd

CFG = get_arch("qwen3-1.7b").reduced()
C = 4

ZERO_VAR = dict(straggler_frac=0.0, base_spread=0.0, jitter=0.0, spike_prob=0.0)


def _fed(mode="async", n=C, **kw):
    base = dict(n_clients=n, local_steps=1, aggregation="dense",
                client_axis="data", data_axis=None, mode=mode)
    base.update(kw)
    return FedConfig(**base)


def _toks(seed=1, n=C):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, CFG.vocab_size, (n, 1, 2, 16)), jnp.int32)}


def _zero_var_lm(n=C, seed=0):
    return ClientLoadModel(n, seed=seed, config=LoadModelConfig(**ZERO_VAR))


def _engine(fed, seed=0, lm=None, timing=None):
    return BufferedAsyncEngine(
        CFG, fed, sgd(0.05), seed=seed,
        load_model=lm or _zero_var_lm(fed.n_clients, seed),
        timing=timing or TimingModel(),
    )


# ------------------------- simulated wall clock ------------------------------

def test_simclock_monotonic():
    c = SimClock()
    assert c.now() == 0.0
    c.advance(2.5)
    assert c.advance_to(4.0) == 1.5
    assert c.now() == 4.0
    assert c.advance_to(4.0) == 0.0  # idempotent at the same instant
    with pytest.raises(ValueError):
        c.advance(-1.0)
    with pytest.raises(ValueError):
        c.advance_to(1.0)


def test_load_model_legacy_step_is_bit_compatible():
    """step() (dt=1) reproduces the pre-SimClock per-round process exactly:
    async and sync platforms keep replaying the same load histories."""
    cfg = LoadModelConfig()
    m = ClientLoadModel(8, seed=3, config=cfg)
    # the legacy recursion, draw order and all
    rng = np.random.default_rng(3)
    n_strag = int(round(cfg.straggler_frac * 8))
    stragglers = rng.choice(8, size=n_strag, replace=False)
    baseline = np.clip(cfg.base_load + cfg.base_spread * rng.standard_normal(8), 0.05, 0.6)
    baseline[stragglers] = cfg.straggler_load
    np.testing.assert_array_equal(m.stragglers, stragglers)
    loads = baseline.copy()
    for _ in range(6):
        innov = cfg.jitter * rng.standard_normal(8)
        loads = cfg.persistence * loads + (1 - cfg.persistence) * baseline + innov
        spikes = rng.random(8) < cfg.spike_prob
        loads = np.clip(np.where(spikes, cfg.spike_load, loads), 0.0, 1.0)
        np.testing.assert_array_equal(m.step(), loads)


def test_load_model_spike_duration_in_sim_seconds():
    """A spike pins the load for spike_duration_s of *simulated* time, not
    one step call — the conflation the SimClock extraction fixed."""
    cfg = LoadModelConfig(**{**ZERO_VAR, "spike_prob": 1.0}, spike_duration_s=1.0)
    m = ClientLoadModel(3, seed=0, config=cfg)
    m.step(0.25)  # every client spikes at t=0.25; active until 1.25
    assert (m.loads == cfg.spike_load).all()
    m.cfg = LoadModelConfig(**ZERO_VAR, spike_duration_s=1.0)  # no new arrivals
    m.step(0.25)  # t=0.5 < 1.25: still spiked, across a step boundary
    assert (m.loads == cfg.spike_load).all()
    m.step(2.0)  # t=2.5 > 1.25: spike over, AR decays off the spike level
    assert (m.loads < cfg.spike_load).all()
    assert m.t == pytest.approx(2.5)


def test_load_model_rejects_negative_dt():
    with pytest.raises(ValueError):
        ClientLoadModel(2, seed=0).step(-0.5)


# --------------------- sync-equivalence contract -----------------------------

@pytest.mark.parametrize("mode", ["dense", "eq6"])
def test_full_buffer_async_bitwise_equals_flat_sync(mode):
    """buffer_size == C, zero load variance, alpha = 0: the async engine
    reproduces the flat sync round bit-for-bit — params, opt moments, agg
    state, and per-round loss."""
    fed_a = _fed("async", aggregation=mode, topn=2, buffer_size=C, staleness_alpha=0.0)
    eng = _engine(fed_a)
    fed_s = _fed("sync", aggregation=mode, topn=2)
    opt = sgd(0.05)
    state = R.make_state(CFG, fed_s, opt, jax.random.key(0))
    fr = R.jit_fed_round(R.build_fed_round(CFG, fed_s, opt))
    for r in range(2):
        rec = eng.step_round(_toks(r))
        state, m = fr(state, _toks(r), R.uniform_weights(C))
        assert rec.staleness == [0] * C  # a full buffer can never be stale
        assert float(m["loss"]) == rec.loss
    np.testing.assert_array_equal(np.asarray(state["params"]), np.asarray(eng.state["params"]))
    for x, y in zip(jax.tree.leaves(state["opt"]), jax.tree.leaves(eng.state["opt"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(state["agg"]), jax.tree.leaves(eng.state["agg"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------- event-queue determinism ---------------------------

def test_event_queue_tiebreak_by_client_id():
    """Zero variance -> every completion ties; the heap's (time, client)
    ordering must stage clients in id order, replay after replay."""
    fed = _fed(buffer_size=2)
    runs = []
    for _ in range(2):
        eng = _engine(fed)
        runs.append([eng.step_round(_toks(r)).participants for r in range(4)])
    assert runs[0] == runs[1]  # deterministic replay
    # all four dispatched at t=0 with equal durations: ids pop in order,
    # and each flush's redispatches land behind the still-queued ties
    assert runs[0][0] == [0, 1] and runs[0][1] == [2, 3]


def test_buffered_flush_preserves_in_flight_rows():
    """In-flight clients keep the version they were dispatched with: after
    one K=2 flush, the two unstaged rows still hold the initial dispatch."""
    fed = _fed(buffer_size=2)
    eng = _engine(fed)
    before = np.array(np.asarray(eng.state["params"]))
    rec = eng.step_round(_toks(0))
    after = np.asarray(eng.state["params"])
    in_flight = [c for c in range(C) if c not in rec.participants]
    assert in_flight  # K < C leaves someone in flight
    for c in in_flight:
        np.testing.assert_array_equal(after[c], before[c])
    for c in rec.participants:  # staged rows redispatch with the new global
        assert not np.array_equal(after[c], before[c])
    np.testing.assert_array_equal(after[rec.participants[0]], after[rec.participants[1]])


def test_async_staleness_accumulates_for_slow_clients():
    fed = _fed(buffer_size=2, staleness_alpha=0.5)
    lm = _zero_var_lm()
    lm.baseline = lm.loads = np.array([0.1, 0.1, 0.9, 0.9])  # 2 stragglers
    eng = _engine(fed, lm=lm)
    stale = []
    for r in range(8):  # enough flushes for the ~10x-slower pair to land
        stale += eng.step_round(_toks(r)).staleness
    assert max(stale) >= 1  # straggler updates landed against newer versions


# --------------------------- max_staleness drops -----------------------------

def test_max_staleness_drops_are_counted_not_lost():
    fed = _fed(n=3, buffer_size=1, staleness_alpha=0.5, max_staleness=1)
    lm = _zero_var_lm(3)
    lm.baseline = lm.loads = np.array([0.05, 0.1, 0.8])  # client 2 ~5x slower
    eng = _engine(fed, lm=lm, timing=TimingModel(payload_bytes=0.0))
    staged_total = 0
    dropped_per_rec = 0
    for r in range(12):
        rec = eng.step_round(_toks(r, n=3))
        staged_total += len(rec.participants)
        dropped_per_rec += rec.dropped
        assert 2 not in rec.participants or rec.staleness[rec.participants.index(2)] <= 1
    assert eng.dropped_total >= 1  # the straggler's stale updates were dropped
    assert dropped_per_rec == eng.dropped_total  # per-record counts add up
    # nothing silently lost: every completion either staged or was dropped
    assert eng.completions == staged_total + eng.dropped_total
    # the dropped client was redispatched from the current global, so its
    # dispatch version tracks the flushes that dropped it
    assert int(eng.dispatch_version[2]) > 0


# ------------------- staleness weights in the packed reduce ------------------

def test_staleness_weights_need_not_sum_to_one_in_reduce():
    """The flush folds (1+s)^-alpha into the weights operand; the packed
    reducers normalize by their own denominator, so the discounted vector's
    sum is irrelevant — pinned against the explicit normalized oracle."""
    rng = np.random.default_rng(0)
    packed = jnp.asarray(rng.normal(size=(C, 257)), jnp.float32)
    mask = np.array([1, 0, 1, 1], np.float32)
    stal = np.array([0, 0, 2, 5], np.float32)
    w = mask / mask.sum()
    w_disc = (w * (1.0 + stal) ** np.float32(-0.5)).astype(np.float32)
    assert not np.isclose(w_disc.sum(), 1.0)  # the discount broke the sum
    got = packing.weighted_mean(packed, jnp.asarray(w_disc), jnp.asarray(mask))
    wn = w_disc * mask / (w_disc * mask).sum()
    want = np.einsum("c,cn->n", wn, np.asarray(packed))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)
    # same contract through an aggregator (what the flush actually calls)
    fed = _fed("sync")
    spec = packing.PackSpec(257, 1, (packing.LeafSlot("x", (257,), 0, 257, 0, 1),))
    ctx = aggregators.AggContext(cfg=CFG, fed=fed, template=None, spec=spec, mesh=None)
    out, _ = aggregators.get("dense")(ctx).aggregate(packed, jnp.asarray(w_disc), {}, jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(out[0]), want, rtol=1e-5, atol=1e-6)


def test_record_weights_match_discount_formula():
    fed = _fed(buffer_size=2, staleness_alpha=0.7)
    lm = _zero_var_lm()
    lm.baseline = lm.loads = np.array([0.1, 0.1, 0.9, 0.9])
    eng = _engine(fed, lm=lm)
    for r in range(4):
        rec = eng.step_round(_toks(r))
        w = np.zeros(C, np.float32)
        w[rec.participants] = np.float32(1.0 / len(rec.participants))
        s = np.zeros(C, np.float32)
        s[rec.participants] = rec.staleness
        np.testing.assert_allclose(
            rec.weights, w * (1.0 + s) ** np.float32(-0.7), rtol=1e-6
        )


# ------------------------------ validation -----------------------------------

def test_async_config_validation():
    with pytest.raises(ValueError, match="buffer_size"):
        _engine(_fed(buffer_size=C + 1))
    with pytest.raises(ValueError, match="mode='async'"):
        _engine(_fed("sync"))
    with pytest.raises(ValueError, match="participation"):
        _engine(_fed(participation="masked", max_participants=2))
    with pytest.raises(ValueError, match="flat"):
        _engine(_fed(state_layout="tree"))
    with pytest.raises(ValueError, match="max_staleness"):
        _engine(_fed(max_staleness=-1))
    with pytest.raises(ValueError, match="mode"):
        R.build_fed_round(CFG, _fed("nope"), sgd())
    with pytest.raises(ValueError, match="mode"):
        FLServer(CFG, _fed("nope"), sgd())
    # the sync builder refuses an async config outright — silently emitting
    # a sync round with buffer_size/staleness ignored would masquerade as
    # the buffered engine
    with pytest.raises(ValueError, match="BufferedAsyncEngine"):
        R.build_fed_round(CFG, _fed("async", buffer_size=2), sgd())


def test_timing_model_terms():
    t = TimingModel(base_compute_s=10.0, uplink_b_s=1e6, payload_bytes=2e6)
    assert t.compute_seconds(0.0) == pytest.approx(10.0)
    assert t.compute_seconds(0.5) == pytest.approx(20.0)
    assert t.compute_seconds(1.0) == pytest.approx(10.0 / t.min_headroom)  # floored
    up = client_upload_seconds(t, 3, t.payload_bytes, np.random.default_rng(0))
    np.testing.assert_allclose(up, 2.0)  # 2 MB over 1 MB/s
    loads = np.array([0.0, 0.5, 0.9])
    assert sync_round_seconds(t, loads, up) == pytest.approx(10.0 / 0.1 + 2.0)
    # the mask limits the wait to the selected subset
    assert sync_round_seconds(t, loads, up, mask=np.array([1, 1, 0])) == pytest.approx(22.0)


# ------------------------- platform integration ------------------------------

def test_server_run_async_records_and_feeds_scheduler():
    fed = _fed(buffer_size=2, staleness_alpha=0.5)
    srv = FLServer(CFG, fed, sgd(0.05), load_model=_zero_var_lm())
    with pytest.raises(RuntimeError, match="run_async"):
        srv.run_round(_toks(0))
    hist = srv.fit(iter(_toks(r) for r in range(3)), 3, log=None)
    assert len(hist) == 3
    times = [r.sim_time for r in hist]
    assert times == sorted(times) and times[0] > 0
    assert all(len(r.participants) == 2 and len(r.staleness) == 2 for r in hist)
    # async completions fed the same scheduler quality EMA sync rounds use
    seen = sorted({c for r in hist for c in r.participants})
    assert not np.isnan(srv.scheduler.last_loss[seen]).any()
    # the server's state IS the engine's state; edges unpack as usual
    assert srv.state is srv.engine.state
    assert jax.tree.structure(srv.global_params()) == jax.tree.structure(
        R.make_template(CFG)
    ) or srv.global_params() is not None


def test_global_params_tracks_fresh_global_row():
    """Buffered async: row 0 can hold a stale in-flight dispatch version,
    so checkpoint/eval/serving dispatch must read the engine's global_row
    (the last flush's first staged client), not row 0."""
    fed = _fed(buffer_size=2)
    lm = _zero_var_lm()
    lm.baseline = lm.loads = np.array([0.9, 0.1, 0.1, 0.9])  # client 0 slow
    srv = FLServer(CFG, fed, sgd(0.05), load_model=lm)
    rec = srv.run_async(_toks(0))
    assert rec.participants == [1, 2] and srv.engine.global_row == 1
    p = np.asarray(srv.state["params"])
    assert not np.array_equal(p[0], p[1])  # row 0 = stale in-flight dispatch
    want = R.unpacked_params(CFG, fed, {"params": srv.state["params"][1:2]})
    for a, b in zip(jax.tree.leaves(srv.global_params()), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[0])


def test_sync_server_advances_shared_clock():
    """A sync FLServer handed the platform's shared clock consumes
    simulated time (wait-for-slowest) and reports next_time, so it can
    interleave with async tasks under TaskManager.step_shared_clock;
    without an explicit clock, sync rounds keep the legacy timeless
    cadence."""
    clock = SimClock()
    srv = FLServer(CFG, _fed("sync"), sgd(0.05), load_model=_zero_var_lm(), clock=clock)
    assert srv.next_time() > 0.0  # now + wait-for-slowest estimate
    srv.run_round(_toks(0))
    t1 = clock.now()
    assert t1 > 0.0  # the round consumed simulated time
    srv.run_round(_toks(1))
    assert clock.now() > t1
    # the load process advanced by the same simulated span as the clock —
    # not by one legacy tick per round (the cadence-conflation bug)
    assert srv.load_model.t == pytest.approx(clock.now())
    srv2 = FLServer(CFG, _fed("sync"), sgd(0.05), load_model=_zero_var_lm())
    srv2.run_round(_toks(0))
    assert srv2.clock.now() == 0.0  # legacy: no shared clock, no sim time
    assert srv2.load_model.t == pytest.approx(1.0)  # legacy tick preserved


def test_load_model_ar1_variance_is_cadence_consistent():
    """Stepping dt in one go or in k slices must give the same process
    variance: sparse sampling (the async engine's big inter-event gaps)
    cannot saturate loads at the clip walls."""
    cfg = LoadModelConfig(straggler_frac=0.0, base_spread=0.0, spike_prob=0.0)
    big = ClientLoadModel(4096, seed=5, config=cfg)
    big.step(600.0)  # one sparse step, way past the decorrelation time
    small = ClientLoadModel(4096, seed=6, config=cfg)
    for _ in range(600):
        small.step(1.0)  # dense legacy cadence to the same sim time
    # both sit at the stationary distribution: jitter/sqrt(1-rho^2) ~ 0.13,
    # nowhere near the sqrt(dt) blow-up (~2.0) the naive scaling produced
    assert abs(np.std(big.loads) - np.std(small.loads)) < 0.03
    assert np.std(big.loads) < 0.3


def test_task_manager_interleaves_on_shared_clock():
    """An 'async' task (event-queue ETAs) and a sync task (now + round
    period) advance in simulated-completion order, not round-robin."""
    clock = SimClock()
    order = []

    def mk(tid, durations):
        times = iter(durations)
        pending = [None]

        def nt():
            if pending[0] is None:
                pending[0] = clock.now() + next(times)
            return pending[0]

        def run(r):
            t = nt()
            clock.advance_to(t)
            pending[0] = None
            order.append((tid, t))
            return {"round": r, "t": t}

        return FederatedTask(tid, "x", len(durations), run, next_time=nt)

    tm = TaskManager(clock=clock)
    tm.register(mk("async", [10.0, 15.0, 30.0]))  # flushes at t=10, 25, 55
    tm.register(mk("sync", [20.0, 20.0]))  # rounds at t=20, 40
    tm.run_to_completion()
    assert [o[0] for o in order] == ["async", "sync", "async", "sync", "async"]
    assert clock.now() == pytest.approx(55.0)
    assert all(t.rounds_done == t.total_rounds for t in tm.tasks.values())
    # a task with no next_time would report "ready now" forever and starve
    # the clocked tasks — shared-clock mode rejects it loudly instead
    tm.register(FederatedTask("untimed", "x", 1, lambda r: {}))
    with pytest.raises(RuntimeError, match="next_time"):
        tm.step_shared_clock()


def test_two_async_engines_share_one_clock():
    """A peer task can advance the shared clock past another engine's
    queued completions; those events must land 'now' (never a backwards
    clock error, never a failed task)."""
    clock = SimClock()
    fed = _fed(buffer_size=2)
    a = BufferedAsyncEngine(CFG, fed, sgd(0.05), seed=0, clock=clock,
                            load_model=_zero_var_lm(seed=0), timing=TimingModel())
    slow = _zero_var_lm(seed=1)
    slow.baseline = slow.loads = np.full(C, 0.6)  # B's fleet ~2x slower
    b = BufferedAsyncEngine(CFG, fed, sgd(0.05), seed=1, clock=clock,
                            load_model=slow, timing=TimingModel())
    for r in range(3):  # A's flushes race the clock past B's queued events
        a.step_round(_toks(r))
    assert clock.now() > b.next_completion_time()  # B's events are past due
    rec = b.step_round(_toks(9))  # lands "now" instead of raising
    assert rec.sim_time == clock.now() and np.isfinite(rec.loss)
    assert rec.participants and rec.staleness == [0, 0]


def test_task_manager_without_clock_keeps_fair_share():
    tm = TaskManager()
    calls = []
    tm.register(FederatedTask("a", "x", 2, lambda r: calls.append("a") or {}))
    tm.register(FederatedTask("b", "x", 2, lambda r: calls.append("b") or {}))
    tm.run_to_completion()
    assert calls == ["a", "b", "a", "b"]  # lockstep round-robin, unchanged
    with pytest.raises(RuntimeError, match="SimClock"):
        tm.step_shared_clock()


def test_monitor_renders_async_records():
    recs = [
        AsyncRoundRecord(round_idx=i, loss=2.0 - 0.1 * i, weights=[0.5, 0.5, 0.0],
                         seconds=0.1, participants=[0, 1], loads=[0.2, 0.3, 0.9],
                         version=i + 1, sim_time=30.0 * (i + 1),
                         staleness=[0, i], dropped=i % 2)
        for i in range(3)
    ]
    txt = monitor.render_task("demo", recs, 3)
    assert "sim clock 90s" in txt and "dropped 1" in txt and "staleness" in txt
    data = json.loads(monitor.export_json("demo", recs, 3))
    assert data["rounds"][-1]["sim_time"] == pytest.approx(90.0)
    assert data["rounds"][-1]["staleness"] == [0, 2]
    # sync records still render without the async line
    from repro.core.server import RoundRecord

    sync_txt = monitor.render_task(
        "s", [RoundRecord(0, 1.0, [1.0], 0.1)], 1
    )
    assert "sim clock" not in sync_txt


def test_train_cli_async():
    root = Path(__file__).resolve().parents[1]
    env = {**os.environ, "PYTHONPATH": str(root / "src"), "JAX_PLATFORMS": "cpu"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
         "--rounds", "3", "--clients", "3", "--batch", "2", "--seq", "32",
         "--mode", "async", "--buffer-size", "2", "--max-staleness", "4"],
        env=env, cwd=root, capture_output=True, text=True, timeout=420,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["mode"] == "async" and out["rounds"] == 3
    assert out["sim_seconds"] > 0 and out["dropped"] == 0
