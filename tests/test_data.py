"""Data pipeline: Darknet annotation format, partitioning, target building."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_arch
from repro.core.rounds import FedConfig
from repro.data import darknet, partition, synthetic
from repro.data.pipeline import fed_batches
from repro.models.yolov3 import ANCHORS

bbox_st = st.builds(
    darknet.BBox,
    label=st.integers(0, 9),
    x=st.floats(0.05, 0.95),
    y=st.floats(0.05, 0.95),
    w=st.floats(0.01, 0.5),
    h=st.floats(0.01, 0.5),
)


@given(st.lists(bbox_st, max_size=8))
@settings(max_examples=25, deadline=None)
def test_darknet_roundtrip(boxes):
    text = darknet.write_annotation(boxes)
    back = darknet.parse_annotation(text)
    assert len(back) == len(boxes)
    for a, b in zip(boxes, back):
        assert a.label == b.label
        np.testing.assert_allclose([a.x, a.y, a.w, a.h], [b.x, b.y, b.w, b.h], atol=1e-5)


def test_darknet_rejects_malformed():
    with pytest.raises(ValueError):
        darknet.parse_annotation("0 0.5 0.5 0.1")  # 4 fields
    with pytest.raises(ValueError):
        darknet.parse_annotation("0 1.5 0.5 0.1 0.1")  # out of range


def test_darknet_skips_comments_and_blanks():
    boxes = darknet.parse_annotation("# header\n\n1 0.5 0.5 0.2 0.2\n")
    assert len(boxes) == 1 and boxes[0].label == 1


def test_map_annotations(tmp_path):
    src = tmp_path / "cam0"
    src.mkdir()
    (src / "img1.txt").write_text("0 0.5 0.5 0.2 0.2")
    (src / "img2.txt").write_text("1 0.25 0.25 0.1 0.1\n2 0.75 0.75 0.1 0.1")
    out = darknet.map_annotations(src, tmp_path / "train")
    assert set(out) == {"img1", "img2"}
    assert (tmp_path / "train" / "img2.txt").exists()


def test_build_targets_places_objects():
    boxes = [[darknet.BBox(1, 0.51, 0.26, 0.2, 0.2)]]
    tgts = darknet.build_targets(boxes, [8, 4, 2], 3, 3, ANCHORS)
    t0 = tgts[0]
    assert t0["obj"].sum() == 1.0
    gy, gx = np.argwhere(t0["obj"][0].sum(-1))[0][:2]
    assert (gx, gy) == (int(0.51 * 8), int(0.26 * 8))
    assert t0["cls"][0, gy, gx].sum() == 1.0


def test_iid_partition_covers_all():
    parts = partition.iid_partition(103, 5, np.random.default_rng(0))
    joined = np.concatenate(parts)
    assert len(joined) == 103 and len(set(joined.tolist())) == 103


@given(st.integers(2, 8), st.floats(0.05, 10.0))
@settings(max_examples=15, deadline=None)
def test_dirichlet_partition_properties(n_clients, alpha):
    rng = np.random.default_rng(1)
    labels = rng.integers(0, 5, 400)
    parts = partition.dirichlet_partition(labels, n_clients, alpha, rng)
    joined = np.concatenate(parts)
    assert len(joined) == 400 and len(set(joined.tolist())) == 400  # exact cover
    assert all(len(p) >= 1 for p in parts)


def test_dirichlet_partition_deterministic_under_fixed_seed():
    labels = np.random.default_rng(0).integers(0, 6, 500)
    a = partition.dirichlet_partition(labels, 5, 0.3, np.random.default_rng(42))
    b = partition.dirichlet_partition(labels, 5, 0.3, np.random.default_rng(42))
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_quantity_skew_partition_covers_and_skews():
    rng = np.random.default_rng(1)
    parts = partition.quantity_skew_partition(1000, 6, rng, sigma=1.5, min_per_client=4)
    joined = np.concatenate(parts)
    assert len(joined) == 1000 and len(set(joined.tolist())) == 1000
    sizes = sorted(len(p) for p in parts)
    assert sizes[0] >= 4 and sizes[-1] > 2 * sizes[0]  # a real long tail


def test_class_shard_partition_limits_classes_per_client():
    rng = np.random.default_rng(2)
    labels = np.repeat(np.arange(10), 40)
    parts = partition.class_shard_partition(labels, 5, 2, rng)
    joined = np.concatenate(parts)
    assert len(joined) == 400 and len(set(joined.tolist())) == 400
    # 2 contiguous label shards -> at most ~3 distinct classes per client
    assert max(len(set(labels[p].tolist())) for p in parts) <= 4


def test_ensure_min_reaches_fixed_point_even_when_donor_dips():
    # the donor (5 elems) must itself be topped back up after giving 4 away
    out = [np.array([], int), np.arange(0, 5), np.arange(5, 12)]
    fixed = partition._ensure_min(out, 4)
    assert all(len(p) >= 4 for p in fixed)
    joined = np.concatenate(fixed)
    assert len(joined) == 12 and len(set(joined.tolist())) == 12
    with pytest.raises(ValueError, match="infeasible"):
        partition._ensure_min([np.arange(3), np.arange(3, 5)], 4)


def test_make_scenario_dispatch_and_unknown():
    labels = np.random.default_rng(3).integers(0, 4, 200)
    for name in partition.SCENARIOS:
        parts = partition.make_scenario(name, labels, 4, np.random.default_rng(7))
        assert len(np.concatenate(parts)) == 200
    with pytest.raises(ValueError, match="scenario"):
        partition.make_scenario("nope", labels, 4, np.random.default_rng(7))


def test_partitioned_token_batches_shapes_and_scenarios():
    cfg = get_arch("qwen3-1.7b").reduced()
    fed = FedConfig(n_clients=3, local_steps=2, client_axis="data")
    it = fed_batches(cfg, fed, batch=2, seq=24, partition_name="dirichlet", alpha=0.1)
    batch = next(it)
    assert batch["tokens"].shape == (3, 2, 2, 24)
    assert batch["tokens"].dtype == np.int32
    # yolo archs route partition scenarios through the detection suite (PR 3)
    det = next(fed_batches(get_arch("fedyolov3").reduced(), fed, batch=2, seq=0,
                           img_size=32, partition_name="dirichlet"))
    assert det["images"].shape[:3] == (3, 2, 2) and len(det["targets"]) == 3
    # other modalities still reject scenario splits
    with pytest.raises(ValueError, match="text"):
        next(fed_batches(get_arch("hubert-xlarge").reduced(), fed, batch=2, seq=8,
                         partition_name="dirichlet"))


def test_dirichlet_skew_increases_with_small_alpha():
    rng = np.random.default_rng(2)
    labels = rng.integers(0, 8, 4000)
    skew_lo = partition.partition_stats(
        partition.dirichlet_partition(labels, 4, 0.05, np.random.default_rng(3)), labels
    )["skew_tv"].mean()
    skew_hi = partition.partition_stats(
        partition.dirichlet_partition(labels, 4, 100.0, np.random.default_rng(3)), labels
    )["skew_tv"].mean()
    assert skew_lo > skew_hi


def test_markov_tokens_deterministic_structure():
    src = synthetic.MarkovTokens(64, seed=0)
    a = src.sample(np.random.default_rng(0), 2, 50)
    b = src.sample(np.random.default_rng(0), 2, 50)
    np.testing.assert_array_equal(a, b)
    assert a.max() < 64


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "hubert-xlarge", "llava-next-34b", "fedyolov3"])
def test_fed_batches_shapes(arch):
    cfg = get_arch(arch).reduced()
    fed = FedConfig(n_clients=2, local_steps=2, client_axis="data")
    it = fed_batches(cfg, fed, batch=2, seq=32, img_size=32)
    batch = next(it)
    if cfg.family == "yolo":
        assert batch["images"].shape == (2, 2, 2, 32, 32, 3)
        assert len(batch["targets"]) == 3
        assert batch["targets"][0]["obj"].shape[:3] == (2, 2, 2)
    elif cfg.modality == "audio":
        assert batch["frames"].shape == (2, 2, 2, 32, cfg.d_model)
    elif cfg.modality == "vlm":
        assert batch["tokens"].shape[3] + batch["images"].shape[3] == 32
    else:
        assert batch["tokens"].shape == (2, 2, 2, 32)
