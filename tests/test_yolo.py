"""FedYOLOv3 — the paper's model: loss Eqs 2-4 behaviour + federated training."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import darknet, synthetic
from repro.models import params as P
from repro.models import yolov3
from repro.models.yolov3 import ANCHORS

CFG = get_arch("fedyolov3")


def _batch(B=2, size=64, seed=0):
    rng = np.random.default_rng(seed)
    imgs, boxes = synthetic.scene_images(rng, B, size, CFG.vocab_size)
    grids = [size // 8, size // 16, size // 32]
    tgts = darknet.build_targets(boxes, grids, CFG.n_heads, CFG.vocab_size, ANCHORS)
    return {
        "images": jnp.asarray(imgs),
        "targets": [{k: jnp.asarray(v) for k, v in t.items()} for t in tgts],
    }


def test_forward_shapes():
    params = P.init_params(yolov3.template(CFG), jax.random.key(0))
    outs = yolov3.forward(params, jnp.zeros((2, 64, 64, 3)), CFG)
    assert len(outs) == 3
    assert outs[0].shape == (2, 8, 8, 3, 5 + CFG.vocab_size)
    assert outs[2].shape == (2, 2, 2, 3, 5 + CFG.vocab_size)


def test_iou_identity_and_disjoint():
    box = jnp.asarray([0.5, 0.5, 0.2, 0.2])
    assert float(yolov3.iou(box, box)) == 1.0
    other = jnp.asarray([0.1, 0.1, 0.05, 0.05])
    assert float(yolov3.iou(box, other)) == 0.0


def test_loss_finite_and_decreases():
    params = P.init_params(yolov3.template(CFG), jax.random.key(1))
    batch = _batch()
    from repro.optim import sgd

    opt = sgd(lr=1e-3)
    st = opt.init(params)
    losses = []
    grad_fn = jax.jit(jax.value_and_grad(lambda p: yolov3.yolo_loss(p, batch, CFG), has_aux=True))
    for _ in range(8):
        (loss, m), g = grad_fn(params)
        params, st = opt.update(params, g, st)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_noobj_weighting():
    """Confidence loss on empty cells is down-weighted by lambda_noobj."""
    assert yolov3.LAMBDA_NOOBJ < 1.0 < yolov3.LAMBDA_COORD


def test_federated_yolo_round():
    """FedYOLOv3 = the paper's headline: YOLO under the HFL engine."""
    from repro.core import rounds as R
    from repro.core.rounds import FedConfig
    from repro.data.pipeline import fed_batches
    from repro.optim import sgd

    fed = FedConfig(n_clients=2, local_steps=1, aggregation="eq6", topn=3, client_axis="data", data_axis=None)
    opt = sgd(lr=1e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        batch = jax.tree.map(jnp.asarray, next(fed_batches(CFG, fed, batch=2, seq=0, img_size=64)))
        losses = []
        for _ in range(6):  # overfit one fixed batch -> must decrease
            state, m = fr(state, batch, R.uniform_weights(2))
            losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
