"""MoE dispatch implementations: GShard grouped vs sort-based gather/scatter."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import moe as M
from repro.models.params import init_params


def _cfg(capacity_factor=8.0, group=4096):
    cfg = get_arch("granite-moe-1b-a400m").reduced()
    return dataclasses.replace(cfg, capacity_factor=capacity_factor, moe_group_size=group)


def _setup(cfg, B=2, S=16, seed=0):
    tpl = M.moe_template(cfg, (), ())
    p = init_params(tpl, jax.random.key(seed), jnp.float32)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(B, S, cfg.d_model)) * 0.5, jnp.float32)
    return p, x


def test_sort_equals_gshard_dropfree():
    cfg = _cfg()
    p, x = _setup(cfg)
    y1, a1 = M.moe_block(p, x, cfg)
    y2, a2 = M.moe_block_sort(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_sort_gradients_match_gshard():
    cfg = _cfg()
    p, x = _setup(cfg)
    g1 = jax.grad(lambda p_: M.moe_block(p_, x, cfg)[0].sum())(p)
    g2 = jax.grad(lambda p_: M.moe_block_sort(p_, x, cfg)[0].sum())(p)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]), rtol=5e-4, atol=5e-5)


def test_grouping_preserves_output_when_groups_divide():
    """Same tokens, gs=S vs gs=S/2: outputs differ only via capacity; with
    high capacity they must be identical (routing is per-token)."""
    cfg_big = _cfg(group=32)
    cfg_small = _cfg(group=16)
    p, x = _setup(cfg_big, S=32)
    y1, _ = M.moe_block(p, x, cfg_big)
    y2, _ = M.moe_block(p, x, cfg_small)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-5)


def test_moe_impl_config_switch():
    cfg = dataclasses.replace(_cfg(), moe_impl="sort")
    p, x = _setup(cfg)
    y, aux = M.moe_block(p, x, cfg)  # dispatches to sort path
    assert y.shape == x.shape and bool(jnp.isfinite(aux))


def test_capacity_drops_tokens_when_low():
    cfg = _cfg(capacity_factor=0.1)
    p, x = _setup(cfg, S=32)
    y_low, _ = M.moe_block(p, x, cfg)
    y_high, _ = M.moe_block(p, x, _cfg(capacity_factor=8.0, group=cfg.moe_group_size))
    # low capacity must actually change (drop) some outputs
    assert float(jnp.max(jnp.abs(y_low - y_high))) > 1e-4
