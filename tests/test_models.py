"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED variant, runs one forward + one train step on CPU with shape and
finiteness assertions; decode-capable archs also verify that
prefill+decode_step exactly matches the full forward."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_arch
from repro.core import rounds as R
from repro.models import params as P
from repro.models import serving as S
from repro.models import transformer as T
from repro.optim import sgd

ARCH_IDS = [c.name for c in ASSIGNED]


def reduced_cfg(name):
    cfg = get_arch(name).reduced()
    if cfg.n_experts:  # drop-free routing for decode-equivalence checks
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


def make_batch(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    if cfg.modality == "audio":
        return {
            "frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            "mask": jnp.asarray(rng.random((B, S)) < 0.4),
        }
    if cfg.modality == "vlm":
        ni = cfg.n_image_tokens
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - ni)), jnp.int32),
            "images": jnp.asarray(rng.normal(size=(B, ni, cfg.d_model)) * 0.1, jnp.float32),
        }
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_cfg(arch)
    assert cfg.n_layers <= max(2, cfg.local_global_period) and cfg.d_model <= 512
    tpl = T.template(cfg)
    params = P.init_params(tpl, jax.random.key(0), jnp.float32)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: T.loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # one SGD train step must change params and stay finite
    opt = sgd(lr=0.1)
    st = opt.init(params)
    (l2, _), grads = jax.jit(jax.value_and_grad(lambda p: T.loss_fn(cfg, p, batch), has_aux=True))(params)
    new_params, _ = opt.update(params, grads, st)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads)), arch
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if get_arch(a).has_decode])
def test_decode_matches_full_forward(arch):
    cfg = reduced_cfg(arch)
    tpl = T.template(cfg)
    params = P.init_params(tpl, jax.random.key(1), jnp.float32)
    B, Sq = 2, 32
    toks = jax.random.randint(jax.random.key(2), (B, Sq + 1), 0, cfg.vocab_size)
    ni = cfg.n_image_tokens if cfg.modality == "vlm" else 0
    imgs = (
        jax.random.normal(jax.random.key(4), (B, ni, cfg.d_model)) * 0.1 if ni else None
    )

    def mk(tok_slice):
        b = {"tokens": tok_slice}
        if ni:
            b["images"] = imgs
        return b

    logits_pre, cache = S.prefill(cfg, params, mk(toks[:, :Sq]), max_len=ni + Sq + 8)
    # prefill last-token logits == full forward last position
    hidden, _ = T.trunk(cfg, params, T.embed_inputs(cfg, params, mk(toks[:, :Sq])))
    full_last = T.logits_fn(cfg, params, hidden)[:, -1]
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]), np.asarray(full_last), rtol=5e-4, atol=5e-4)
    # one decode step == full forward at position ni+Sq
    logits_dec, _ = S.decode_step(cfg, params, cache, toks[:, Sq:], jnp.int32(ni + Sq))
    hidden2, _ = T.trunk(cfg, params, T.embed_inputs(cfg, params, mk(toks)))
    want = T.logits_fn(cfg, params, hidden2)[:, ni + Sq]
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]), np.asarray(want), rtol=5e-3, atol=5e-3)


def test_encoder_only_has_no_decode():
    cfg = get_arch("hubert-xlarge")
    assert not cfg.has_decode and not cfg.supports_long_decode


def test_vocab_padding_is_masked():
    cfg = reduced_cfg("granite-3-8b")  # vocab 512 -> padded? reduced vocab=512, multiple of 16
    cfg = dataclasses.replace(cfg, vocab_size=509)  # force padding
    tpl = T.template(cfg)
    params = P.init_params(tpl, jax.random.key(0), jnp.float32)
    h = jnp.zeros((1, 4, cfg.d_model)).at[...].set(0.1)
    logits = T.logits_fn(cfg, params, h)
    assert logits.shape[-1] == 512
    assert bool(jnp.all(logits[..., 509:] < -1e20))


def test_llava_padded_heads_are_dead():
    from repro.models import attention as A

    cfg = get_arch("llava-next-34b")
    assert A.eff_heads(cfg) == 64
    hm = A.head_mask(cfg)
    assert int(hm.sum()) == 56  # 8 groups x 7 real heads
    assert hm.reshape(8, 8)[:, -1].sum() == 0  # last head of each group dead
