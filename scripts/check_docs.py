#!/usr/bin/env python
"""Docs check: every repo path README.md (and DESIGN.md) mentions must exist.

Scans every line of each doc (prose, code spans, and fenced blocks alike)
for path-like tokens — anything containing a '/' or ending in a known
extension — and verifies them against the working tree, so the README's
paper→module map and quickstart can't silently rot as files move. Python
module paths in ``python -m pkg.mod`` commands are resolved too (against
src/ and the repo root; installed tools like pytest are allowed). Exits
non-zero listing any dangling references.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = ["README.md", "DESIGN.md"]
EXTS = (".py", ".md", ".sh", ".json", ".toml")

# tokens that look like paths but aren't repo files
IGNORE = re.compile(r"^(https?:|/|\{|<)")

# filenames the code CREATES at run time (documented directory layouts,
# e.g. a DurableRun dir in DESIGN.md §16) — real names, never repo files
RUNTIME_ARTIFACTS = {"meta.json", "manifest.json"}


def path_tokens(text: str) -> set[str]:
    tokens: set[str] = set()
    for tok in re.findall(r"[\w./-]+", text):
        if IGNORE.match(tok):
            continue
        if "/" in tok and tok.endswith(EXTS):
            tokens.add(tok.rstrip("."))
        elif tok.endswith(EXTS) and tok.count(".") == 1 and "/" not in tok:
            # bare filenames like ROADMAP.md or rounds.py
            tokens.add(tok)
    return tokens


def module_tokens(text: str) -> set[str]:
    return set(re.findall(r"python -m ([\w.]+)", text))


def main() -> int:
    missing: list[str] = []
    for doc in DOCS:
        p = ROOT / doc
        if not p.exists():
            missing.append(f"{doc} (the doc itself)")
            continue
        text = p.read_text()
        for tok in sorted(path_tokens(text)):
            if tok in RUNTIME_ARTIFACTS:
                continue
            # DESIGN.md cites module paths relative to src/repro ("core/rounds.py")
            roots = (ROOT, ROOT / "src", ROOT / "src" / "repro")
            if any((r / tok).exists() for r in roots):
                continue
            if "/" not in tok and any(ROOT.rglob(tok)):
                continue  # bare filename ("rounds.py") cited from a docstring context
            missing.append(f"{doc}: {tok}")
        for mod in sorted(module_tokens(text)):
            rel = mod.replace(".", "/")
            candidates = [
                ROOT / "src" / f"{rel}.py",
                ROOT / f"{rel}.py",
                ROOT / "src" / rel / "__init__.py",
                ROOT / rel / "__init__.py",
            ]
            if any(c.exists() for c in candidates):
                continue
            import importlib.util

            if importlib.util.find_spec(mod.split(".")[0]) is not None:
                continue  # installed tool (e.g. `python -m pytest`)
            missing.append(f"{doc}: module {mod}")
    if missing:
        print("dangling doc references:")
        for m in missing:
            print(f"  {m}")
        return 1
    print(f"docs check OK ({', '.join(DOCS)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
