#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins, runnable from
# anywhere, plus the docs check and a benchmark smoke step. Extra args are
# forwarded to pytest (e.g. scripts/check.sh -k agg).
set -euo pipefail
cd "$(dirname "$0")/.."
python scripts/check_docs.py
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --smoke >/dev/null
echo "benchmark smoke OK"
