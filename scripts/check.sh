#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins, runnable from
# anywhere. Extra args are forwarded to pytest (e.g. scripts/check.sh -k agg).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
