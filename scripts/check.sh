#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins, runnable from
# anywhere, plus the docs check, a test-count floor (suites only grow —
# a collection regression below the PR 2 count fails before pytest runs),
# and a benchmark smoke step. Extra args are forwarded to pytest (e.g.
# scripts/check.sh -k agg).
set -euo pipefail
cd "$(dirname "$0")/.."
python scripts/check_docs.py
TEST_FLOOR=239  # PR 3 collected count; raise, never lower
collected=$(PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest --collect-only -q 2>/dev/null | grep -c '::' || true)
if [ "$collected" -lt "$TEST_FLOOR" ]; then
  echo "FAIL: collected $collected tests < floor $TEST_FLOOR (lost tests?)" >&2
  exit 1
fi
echo "test-count floor OK ($collected >= $TEST_FLOOR)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --smoke >/dev/null
echo "benchmark smoke OK"
