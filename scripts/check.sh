#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins, runnable from
# anywhere, plus the docs check, a test-count floor (suites only grow —
# a collection regression below the PR 5 count fails before pytest runs),
# and a benchmark smoke step. Extra args are forwarded to pytest (e.g.
# scripts/check.sh -k agg).
#
# CI-friendly (.github/workflows/ci.yml runs this verbatim): every phase
# emits a "[check] phase <name> took Ns" timing line so slow phases show
# up in the job log, and a failed collection propagates pytest's own exit
# code (with its log tail) instead of burying it in the floor arithmetic.
set -euo pipefail
cd "$(dirname "$0")/.."

phase_start=$SECONDS
phase() { # phase <name>: report the wall time of the phase that just ended
  echo "[check] phase ${1} took $(( SECONDS - phase_start ))s"
  phase_start=$SECONDS
}

python scripts/check_docs.py
phase docs

TEST_FLOOR=474  # PR 10 collected count; raise, never lower
collect_log=$(mktemp)
collect_status=0
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest --collect-only -q \
  >"$collect_log" 2>&1 || collect_status=$?
if [ "$collect_status" -ne 0 ]; then
  echo "FAIL: pytest collection failed (exit $collect_status)" >&2
  tail -n 40 "$collect_log" >&2
  rm -f "$collect_log"
  exit "$collect_status"
fi
# prefer pytest's own "N tests collected" summary; fall back to counting
# column-0 node ids (warning lines mentioning '::' are indented and must
# not inflate the floor count)
collected=$(grep -Eo '^[0-9]+ tests? collected' "$collect_log" | tail -1 | cut -d' ' -f1 || true)
if [ -z "$collected" ]; then
  collected=$(grep -c '^[^ ]*::' "$collect_log" || true)
fi
rm -f "$collect_log"
if [ "$collected" -lt "$TEST_FLOOR" ]; then
  echo "FAIL: collected $collected tests < floor $TEST_FLOOR (lost tests?)" >&2
  exit 1
fi
echo "test-count floor OK ($collected >= $TEST_FLOOR)"
phase collect

# The wire suites spawn real worker subprocesses; a wedged socket must
# fail the phase with its log tail, never stall CI. Override the budget
# with PYTEST_TIMEOUT_S (seconds) for slow machines.
PYTEST_TIMEOUT_S=${PYTEST_TIMEOUT_S:-3600}
pytest_log=$(mktemp)
pytest_status=0
timeout --signal=TERM --kill-after=30 "$PYTEST_TIMEOUT_S" \
  env PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@" \
  >"$pytest_log" 2>&1 || pytest_status=$?
if [ "$pytest_status" -eq 124 ] || [ "$pytest_status" -eq 137 ]; then
  echo "FAIL: pytest exceeded ${PYTEST_TIMEOUT_S}s (hung socket test?); last 60 log lines:" >&2
  tail -n 60 "$pytest_log" >&2
  rm -f "$pytest_log"
  exit 124
fi
if [ "$pytest_status" -ne 0 ]; then
  tail -n 100 "$pytest_log" >&2
  rm -f "$pytest_log"
  exit "$pytest_status"
fi
tail -n 15 "$pytest_log"
rm -f "$pytest_log"
phase pytest

# the smoke rows land in a file so CI can upload THIS run's numbers as an
# artifact next to the committed BENCH trajectory
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --smoke > BENCH_smoke_rows.csv
echo "benchmark smoke OK ($(wc -l < BENCH_smoke_rows.csv) rows in BENCH_smoke_rows.csv)"
phase bench_smoke
